"""Cross-request prefix KV reuse tests (ISSUE 10): the radix page index
(``repro.kvstore.prefix``), suffix-only lease pricing, scheduler/fleet
integration, and the device seeded-pool path.

- chained chunk hashes: equal prefixes agree, divergence breaks the chain,
  partial tail chunks are never hashed,
- PrefixPageCache: refcounted acquire/release, copy-on-write on divergence
  (no two live leases ever write the same physical page), LRU leaf-first
  eviction under capacity with refs pinned, ``verify_prefix_index`` clean
  after every mutation,
- suffix-only lease math: ``chunk_page_bytes(shared_pages=)`` and the
  ``KVLeaseManager`` high-water mark under sharing match a from-scratch
  analytic byte model to 1e-5; a request refused at full price is ADMITTED
  at the same budget once its prefix is shared,
- cost model: ``prefix_hit_chunks=k`` zeroes compute/wire rows of served
  chunks while later chunks still attend over the cached prefix and the
  feature factorization identity survives,
- scheduler + fleet: prefix ON beats OFF on p99 TTFT with more concurrent
  admissions at equal budget; prefix-affinity ETA quotes and the jsf
  tiebreak; reject-with-retry-after when every cell's headroom is gone,
- device (subprocess, 8 fake devices): a seeded prefix pool with GARBAGE
  tokens in the hit region reproduces the baseline logits bit-identically,
  the ledger/telemetry ``prefix_hit`` rows match the closed-form saved-bytes
  model, the disarmed path lowers to byte-identical HLO, and the armed path
  adds ZERO collectives; the JaxExecutor round trip serves later requests
  from the DeviceSeedCache with bit-identical results.
"""
import math
import os
import subprocess
import sys
from dataclasses import replace as dc_replace

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.fleet import CellSignals, FleetFabric, FleetRouter, score_cells
from repro.kvstore.prefix import (DeviceSeedCache, PrefixPageCache,
                                  chunk_hashes, verify_prefix_index)
from repro.runtime.engine import (ContinuousEngine, EngineConfig, Request,
                                  SimExecutor)
from repro.sched import KVLeaseManager
from repro.sched.kvlease import chunk_page_bytes, request_lease_events

ROOT = os.path.join(os.path.dirname(__file__), "..")

CFG = get_config("llama3-70b")
SEQ = 32768
PREFIX_CHUNKS = 6


def _run(snippet, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout, r.stdout
    return r.stdout


# ------------------------------------------------------------- chunk hashes

def test_chunk_hashes_chained_and_partial_tail():
    toks = np.arange(64, dtype=np.int64)
    h = chunk_hashes(toks, 16)
    assert len(h) == 4
    # equal prefix => equal leading hashes; suffix divergence leaves them
    other = toks.copy()
    other[48:] += 1
    h2 = chunk_hashes(other, 16)
    assert h2[:3] == h[:3] and h2[3] != h[3]
    # chained: a chunk-0 divergence changes EVERY later hash
    early = toks.copy()
    early[0] += 1
    h3 = chunk_hashes(early, 16)
    assert all(a != b for a, b in zip(h3, h))
    # a partial trailing chunk is never hashed
    assert chunk_hashes(toks[:63], 16) == h[:3]
    # explicit per-chunk split (LBCP) must agree with the uniform split
    assert chunk_hashes(toks, [16, 16, 16, 16]) == h
    # a DIFFERENT split hashes differently (hash commits to the split)
    assert chunk_hashes(toks, [32, 32]) != h[:2]
    assert chunk_hashes(toks, 0) == ()


# ---------------------------------------------------------- radix page cache

def test_prefix_cache_acquire_release_cow():
    cache = PrefixPageCache(pages_per_chunk=2, page_bytes=100.0)
    a = chunk_hashes(np.arange(64), 16)
    b = chunk_hashes(np.r_[np.arange(32), np.arange(900, 932)], 16)
    assert a[:2] == b[:2] and a[2] != b[2]

    l0 = cache.acquire(0, a)
    verify_prefix_index(cache)
    assert l0.hit_chunks == 0 and len(l0.new_pages) == 8
    assert cache.match(a) == 4 and cache.hit_pages(a) == 8

    # full hit: refcount++ on every node, zero new pages
    l1 = cache.acquire(1, a)
    verify_prefix_index(cache)
    assert l1.hit_chunks == 4 and l1.new_pages == ()
    assert cache.live_shared_bytes() == 8 * 100.0

    # divergence at chunk 2: copy-on-write — the novel suffix gets FRESH
    # pages, disjoint from every page any other live lease wrote
    l2 = cache.acquire(2, b)
    verify_prefix_index(cache)
    assert l2.hit_chunks == 2 and len(l2.new_pages) == 4
    assert not set(l2.new_pages) & set(l0.new_pages)
    assert cache.resident_pages() == 12  # 4 + 2 divergent chunks

    st = cache.stats()
    assert st["prefix_requests"] == 3 and st["prefix_hits"] == 2
    assert st["prefix_hit_chunks"] == 6 and st["prefix_hit_pages"] == 12
    assert st["prefix_saved_bytes"] == 12 * 100.0
    assert st["prefix_resident_bytes"] == 12 * 100.0

    # release drops refs but keeps nodes cached (that IS the cache)
    for l in (l0, l1, l2):
        cache.release(l)
    verify_prefix_index(cache)
    assert cache.match(a) == 4 and cache.match(b) == 4
    cache.release(l0)  # double release is a no-op
    verify_prefix_index(cache)


def test_prefix_cache_eviction_lru_leaf_first_and_capacity():
    cache = PrefixPageCache(pages_per_chunk=1, page_bytes=10.0,
                            capacity_pages=4)
    a = chunk_hashes(np.arange(40), 10)       # 4 chunks -> fills capacity
    la = cache.acquire(0, a)
    assert cache.resident_pages() == 4
    # live refs pin everything: a second chain cannot evict, so its tail is
    # simply not indexed — and its lease still charges full price upstream
    b = chunk_hashes(np.arange(500, 540), 10)
    lb = cache.acquire(1, b)
    assert lb.hit_chunks == 0 and lb.new_pages == ()
    assert cache.match(b) == 0 and cache.evictions == 0
    verify_prefix_index(cache)

    # after release, eviction reclaims LRU LEAVES only, root stays longest
    cache.release(la)
    cache.release(lb)
    lc = cache.acquire(2, chunk_hashes(np.arange(700, 720), 10))  # 2 chunks
    verify_prefix_index(cache)
    assert lc.hit_chunks == 0 and len(lc.new_pages) == 2
    assert cache.evictions == 2
    # chain a survives as a shorter prefix: leaves died first
    assert 0 < cache.match(a) < 4
    # freed handles were recycled, not re-minted
    assert cache._next_page == 4
    cache.release(lc)
    verify_prefix_index(cache)


def test_device_seed_cache_lru_and_prefix_match():
    cache = DeviceSeedCache(max_entries=2)
    cache.put((1, 2, 3), {"k": "A"})
    assert cache.match((1, 2, 3)) == 3
    assert cache.match((1, 2, 9)) == 2      # any snapshot sharing the prefix
    assert cache.match((9, 2, 3)) == 0
    assert cache.lookup((1, 2, 9), 2) == {"k": "A"}
    cache.put((4, 5), {"k": "B"})
    cache.put((6, 7), {"k": "C"})           # bound 2: (1,2,3) evicted
    assert cache.match((1, 2, 3)) == 0
    assert cache.lookup((4, 5), 2) == {"k": "B"}
    assert cache.match((6, 7, 8)) == 2
    cache.put((), {"k": "empty"})           # empty chain is never indexed
    assert cache.match(()) == 0


# ------------------------------------------------------- suffix-only leases

def test_chunk_page_bytes_shared_pages():
    kvb = [4096.0] * 4
    chunks = [1024] * 4
    # page_tokens=512 -> 2 pages per chunk, 2048 bytes each
    got = chunk_page_bytes(kvb, chunks, 4096, 512, shared_pages=[2, 1, 0, 0])
    assert got == [0.0, 2048.0, 4096.0, 4096.0]
    # sharing never goes negative and composes with the seq_len clamp:
    # seq_len=2560 -> chunk 2 touches 1 of its 2 pages, chunk 3 none
    got = chunk_page_bytes(kvb, chunks, 2560, 512, shared_pages=[2, 2, 1, 9])
    assert got == [0.0, 0.0, 0.0, 0.0]
    got = chunk_page_bytes(kvb, chunks, 2560, 512, shared_pages=[2, 2, 0, 0])
    assert got == [0.0, 0.0, 2048.0, 0.0]
    # seq_len=None: sharing applies against the whole-chunk page count
    got = chunk_page_bytes(kvb, chunks, None, 512, shared_pages=[1, 0, 0, 0])
    assert got == [2048.0, 4096.0, 4096.0, 4096.0]
    # no sharing, no seq_len: legacy whole-bucket accounting untouched
    assert chunk_page_bytes(kvb, chunks, None, 512) == kvb


def _merged_peak(events):
    """Independent reimplementation of the lease timeline peak: sort
    (time, delta) with frees first at equal timestamps, walk, track max."""
    cur = peak = 0.0
    for _, d in sorted(events):
        cur += d
        peak = max(peak, cur)
    return peak


def test_lease_hwm_under_sharing_matches_analytic_model():
    """ISSUE 10 acceptance: the KVLeaseManager high-water mark under
    sharing equals a from-scratch refcount-weighted byte model to 1e-5 —
    shared pages are charged ONCE (by the radix holder), every request's
    novel suffix at page granularity."""
    n, m = 2, 3
    chunks = [8, 8, 8]
    kvb = [6.0, 6.0, 6.0]
    pair = list(range(n))                     # p2 = m: no MBKR hosting
    pt = 4                                    # 2 pages/chunk, 3.0 per page
    fin0 = np.array([[1.0, 2.0], [2.0, 3.0], [3.0, 4.0]])
    fin1 = fin0 + 0.5
    fin2 = fin0 + 1.0
    shared1 = [2, 2, 0]                       # first two chunks fully shared
    shared2 = [2, 1, 0]                       # partial page sharing
    mgr = KVLeaseManager(n, [100.0, 100.0])
    for rid, (fin, shared) in enumerate(
            [(fin0, None), (fin1, shared1), (fin2, shared2)]):
        lease = request_lease_events(rid, fin, kvb, m, pair,
                                     seq_len=24, chunks=chunks,
                                     page_tokens=pt, shared_pages=shared)
        assert mgr.admit(lease)

    # the model, from scratch: chunk i of request r allocs its charged
    # bytes at fin[i][s] and frees when the tail chunk clears s
    charged = {0: [6.0, 6.0, 6.0],            # full price
               1: [0.0, 0.0, 6.0],            # suffix only
               2: [0.0, 3.0, 6.0]}            # half of chunk 1 is novel
    for s in range(n):
        ev = []
        for rid, fin in enumerate([fin0, fin1, fin2]):
            t_drain = float(fin[m - 1][s])
            for i in range(m):
                b = charged[rid][i]
                if b:
                    ev += [(float(fin[i][s]), b), (t_drain, -b)]
        assert abs(mgr.hwm[s] - _merged_peak(ev)) <= 1e-5, (s, mgr.hwm[s])

    # admits strictly more at equal budget: a 4th full-price overlapping
    # request busts the budget; the SAME request suffix-priced fits
    tight = KVLeaseManager(n, [float(mgr.hwm.max()) + 6.0] * n)
    for rid, (fin, shared) in enumerate(
            [(fin0, None), (fin1, shared1), (fin2, shared2)]):
        assert tight.admit(request_lease_events(
            rid, fin, kvb, m, pair, seq_len=24, chunks=chunks,
            page_tokens=pt, shared_pages=shared))
    fin3 = fin0 + 0.25
    full = request_lease_events(3, fin3, kvb, m, pair, seq_len=24,
                                chunks=chunks, page_tokens=pt)
    assert not tight.admit(full)
    assert tight.refusals == 1
    suffix = request_lease_events(3, fin3, kvb, m, pair, seq_len=24,
                                  chunks=chunks, page_tokens=pt,
                                  shared_pages=[2, 2, 0])
    assert tight.admit(suffix)


# --------------------------------------------------------------- cost model

def test_costmodel_prefix_hit_zeroes_served_chunks():
    sm = cm.StageModel.build(CFG, 16, 1)
    chunks = [2048] * 16
    base = cm.chunk_cost_arrays(sm, chunks, cm.WSC_PAPER)
    k = 5
    dur, comm, kvb, spill, fetch = cm.chunk_cost_arrays(
        sm, chunks, cm.WSC_PAPER, prefix_hit_chunks=k)
    # served chunks: zero compute, zero boundary wire
    assert np.all(dur[:k] == 0) and np.all(comm[:k] == 0)
    # stored bytes unchanged — the pages still occupy the pool; lease
    # accounting subtracts sharing separately (chunk_page_bytes)
    assert np.array_equal(kvb, base[2])
    # later chunks still attend over the full cached prefix: identical cost
    assert np.array_equal(dur[k:], base[0][k:])
    assert np.array_equal(comm[k:], base[1][k:])
    assert np.all(spill == 0) and np.all(fetch == 0)  # no MBKR plan given
    # k clamps to m-1: the tail chunk always runs (it makes the logits)
    dur_all = cm.chunk_cost_arrays(sm, chunks, cm.WSC_PAPER,
                                   prefix_hit_chunks=99)[0]
    assert dur_all[-1] > 0 and np.all(dur_all[:-1] == 0)

    # the feature factorization identity survives prefix pricing
    from repro.core import mbkr
    mplan = mbkr.plan(16, 16)
    arrays = cm.chunk_cost_arrays(sm, chunks, cm.WSC_PAPER, mbkr_plan=mplan,
                                  prefix_hit_chunks=k)
    total = arrays[0] + arrays[1] + arrays[3] + arrays[4]
    x = cm.chunk_cost_features(sm, chunks, cm.WSC_PAPER, mbkr_plan=mplan,
                               prefix_hit_chunks=k)
    theta = cm.profile_theta(cm.WSC_PAPER, sm.tp)
    assert np.allclose(x @ theta, total, rtol=1e-9)
    assert np.all(x[:k] == 0)


# -------------------------------------------------------- sim scheduler e2e

def _ec(**kw):
    return EngineConfig(model=CFG, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                        num_chunks=16, max_batch=8, buckets=(SEQ,),
                        partition="uniform", sa_iters=8, inflight=2, **kw)


def _chains(n_req, n_prefixes=2):
    return [tuple([(i % n_prefixes + 1) * 10_000 + j
                   for j in range(PREFIX_CHUNKS)]
                  + [(i + 1) * 1_000_000 + j
                     for j in range(16 - PREFIX_CHUNKS)])
            for i in range(n_req)]


def _run_sim(mode, chains):
    eng = ContinuousEngine(_ec(prefix_cache=mode), SimExecutor(CFG, cm.WSC_PAPER))
    for i, ch in enumerate(chains):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=SEQ, prefix_hashes=ch))
    eng.run_until_drained()
    return eng


def test_scheduler_prefix_on_beats_off_and_saved_bytes_model():
    """The tentpole acceptance in sim: at EQUAL lease budget, prefix ON
    serves the shared-prefix stream with a strictly better p99 TTFT and at
    least as many concurrent admissions; the saved-bytes stat matches the
    closed-form hit model; the index verifies clean after the run."""
    chains = _chains(8)
    off = _run_sim("off", chains)
    on = _run_sim("on", chains)
    m_off, m_on = off.metrics(), on.metrics()
    assert m_off["completed"] == m_on["completed"] == 8
    assert m_on["p99_ttft"] < m_off["p99_ttft"], (m_on["p99_ttft"],
                                                  m_off["p99_ttft"])
    assert m_on["peak_inflight"] >= m_off["peak_inflight"]
    assert m_on["lease_hwm_frac"] <= 1.0 + 1e-9

    # off never touches the radix: no prefix keys, no stats
    assert off.prefix_cache is None and off.prefix_stats() == {}
    assert "prefix_hit_rate" not in m_off

    st = on.prefix_stats()
    # fcfs over 2 interleaved prefixes: first request of each misses, the
    # other 6 hit their full 6 shared chunks
    assert st["prefix_requests"] == 8 and st["prefix_hits"] == 6
    assert st["prefix_hit_chunks"] == 6 * PREFIX_CHUNKS
    ppc = on.prefix_cache.pages_per_chunk
    assert st["prefix_hit_pages"] == 6 * PREFIX_CHUNKS * ppc
    # closed-form saved bytes: hit pages x the index's page_bytes
    want = 6 * PREFIX_CHUNKS * ppc * on.prefix_cache.page_bytes
    assert st["prefix_saved_bytes"] == pytest.approx(want, rel=1e-12)
    assert m_on["prefix_hit_rate"] == pytest.approx(6 / 8)
    verify_prefix_index(on.prefix_cache)


def test_prefix_min_pages_gates_pricing():
    """With the hit floor above every possible hit, pricing falls back to
    full price — the run's timing is EXACTLY the prefix-off run on the same
    virtual clock — while the radix index still records residency."""
    chains = _chains(4, n_prefixes=1)

    def run(**kw):
        eng = ContinuousEngine(_ec(**kw), SimExecutor(CFG, cm.WSC_PAPER))
        for i, ch in enumerate(chains):
            eng.submit(Request(rid=i, arrival=0.0, seq_len=SEQ,
                               prefix_hashes=ch))
        eng.run_until_drained()
        return eng

    off = run(prefix_cache="off")
    gated = run(prefix_cache="on", prefix_min_pages=10 ** 9)
    m_off, m_gated = off.metrics(), gated.metrics()
    assert m_gated["completed"] == m_off["completed"] == 4
    for key in ("p99_ttft", "makespan", "peak_inflight"):
        assert m_gated[key] == m_off[key], key
    # the index itself still matched — only the pricing was gated
    assert gated.prefix_stats()["prefix_hits"] == 3
    verify_prefix_index(gated.prefix_cache)


def test_estimate_admission_prefix_affinity_quote():
    """A cell already holding the prefix quotes a strictly earlier ETA for
    the same request, and exposes the hit through prefix_hit_pages — the
    two fleet affinity signals."""
    chains = _chains(2, n_prefixes=1)
    eng = ContinuousEngine(_ec(prefix_cache="on"),
                           SimExecutor(CFG, cm.WSC_PAPER))
    eng.submit(Request(rid=0, arrival=0.0, seq_len=SEQ,
                       prefix_hashes=chains[0]))
    eng.run_until_drained()
    eta_hit, fits_hit = eng.estimate_admission(SEQ, prefix_hashes=chains[1])
    cold = tuple(99_000 + j for j in range(16))
    eta_cold, _ = eng.estimate_admission(SEQ, prefix_hashes=cold)
    eta_none, _ = eng.estimate_admission(SEQ)
    assert eta_hit < eta_cold and eta_cold == eta_none
    ppc = eng.prefix_cache.pages_per_chunk
    assert eng.prefix_hit_pages(chains[1]) == PREFIX_CHUNKS * ppc
    assert eng.prefix_hit_pages(cold) == 0
    # preview is PURE: quoting consumed no radix refs, admitted nothing
    assert eng.prefix_stats()["prefix_requests"] == 1


# -------------------------------------------------------------------- fleet

def test_jsf_prefix_affinity_tiebreak_order():
    def sig(i, hit, free=100.0, eta=1.0):
        return CellSignals(name=f"c{i}", index=i, eta=eta, lease_fits=True,
                           free_lease_bytes=free, queue_depth=0,
                           prefix_hit_pages=hit)
    # equal ETA/fit: the cell holding the prefix wins even with LESS free
    ranked = score_cells("jsf", [sig(0, 0, free=500.0), sig(1, 12)])
    assert ranked[0][1].name == "c1"
    # eta still dominates the tiebreak
    ranked = score_cells("jsf", [sig(0, 0, eta=0.5), sig(1, 12)])
    assert ranked[0][1].name == "c0"


def test_fleet_routes_to_the_prefix_holding_cell():
    """Two identical cells, equally loaded: the cell whose radix already
    holds the request's prefix quotes the shorter effective sequence and
    takes the request — prefix affinity end to end."""
    cells = {f"c{i}": ContinuousEngine(_ec(prefix_cache="on"),
                                       SimExecutor(CFG, cm.WSC_PAPER))
             for i in range(2)}
    fab = FleetFabric(cells, FleetRouter("jsf"))
    chain_a = _chains(1, n_prefixes=1)[0]
    chain_b = tuple(h + 777_000_000 for h in chain_a)
    # warm both cells with EQUAL work but different prefixes
    d0 = fab.submit(Request(rid=0, arrival=0.0, seq_len=SEQ,
                            prefix_hashes=chain_a))
    d1 = fab.submit(Request(rid=1, arrival=0.0, seq_len=SEQ,
                            prefix_hashes=chain_b))
    assert d0.cell != d1.cell
    # a repeat of prefix B must land on B's cell, A's on A's cell
    d2 = fab.submit(Request(rid=2, arrival=0.0, seq_len=SEQ,
                            prefix_hashes=tuple(chain_b[:PREFIX_CHUNKS])
                            + tuple(5_000_000 + j for j in range(10))))
    assert d2.cell == d1.cell
    assert max(s.prefix_hit_pages for s in d2.signals) > 0
    d3 = fab.submit(Request(rid=3, arrival=0.0, seq_len=SEQ,
                            prefix_hashes=tuple(chain_a[:PREFIX_CHUNKS])
                            + tuple(6_000_000 + j for j in range(10))))
    assert d3.cell == d0.cell
    fab.pump()
    assert fab.metrics()["completed"] == 4


class _FullCell:
    """Minimal CellHandle stand-in: finite ETA quote, zero lease headroom."""

    draining = False

    def __init__(self, eta):
        self._eta = eta

    def estimate_admission(self, seq_len, arrival=0.0, prefix_hashes=None):
        return self._eta, False

    def free_lease_bytes(self):
        return 0.0

    def queue_depth(self):
        return 1

    def prefix_hit_pages(self, prefix_hashes):
        return 0

    def records(self):
        return []

    def run_until_drained(self):
        pass

    def poll(self):
        return []

    def submit(self, req):  # pragma: no cover - must never be reached
        raise AssertionError("fabric submitted to a rejected placement")


def test_fleet_reject_with_retry_after():
    """ISSUE 10 satellite: when EVERY live cell's lease headroom is
    exhausted the router rejects with an explicit retry_after (the earliest
    quoted ETA) instead of queueing forever; the fabric submits nothing and
    the rejection lands in the fleet summary."""
    fab = FleetFabric({"a": _FullCell(5e9), "b": _FullCell(3e9)},
                      FleetRouter("jsf"))
    dec = fab.submit(Request(rid=0, arrival=0.0, seq_len=SEQ))
    assert dec.rejected and dec.cell == ""
    assert dec.retry_after == 3e9
    assert fab.placements == {}
    m = fab.metrics()
    assert m["router_rejections"] == 1 and m["rejected"] == 1
    # a live cell with headroom ends the rejections: placement resumes
    fab.add_cell("c", ContinuousEngine(_ec(prefix_cache="off"),
                                       SimExecutor(CFG, cm.WSC_PAPER)))
    dec2 = fab.submit(Request(rid=1, arrival=0.0, seq_len=SEQ))
    assert not dec2.rejected and dec2.cell == "c"
    fab.pump()
    m = fab.metrics()
    assert m["completed"] == 1 and m["router_rejections"] == 1


def test_fleet_retry_after_inf_when_no_finite_quote():
    fab = FleetFabric({"a": _FullCell(math.inf)}, FleetRouter("jsf"))
    dec = fab.submit(Request(rid=0, arrival=0.0, seq_len=SEQ))
    assert dec.rejected and math.isinf(dec.retry_after)


# ----------------------------------------------------------- device parity

SNIPPET_DEVICE_PARITY = r"""
import os, re, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import get_smoke_config, RunConfig
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.obs import telemetry as obs_t

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype="float32")
n = m = 8; s = 128; b = 2
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh, stage_axis="data", tp_axis="model")
plan = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n,
                                          remote_attn="fetch"))
model = build_model(cfg)
params = model.init(jax.random.key(0))
staged = pp.stage_params(cfg, params, plan)
compat.set_mesh(mesh)
toks = np.random.default_rng(0).integers(
    0, cfg.vocab_size, (b, s)).astype(np.int32)

# 1) disarmed is the SAME program: byte-identical HLO text, not merely
#    zero extra collectives (the PR 6/8 discipline)
base_low = jax.jit(lambda st, tk: pp.prefill_pipeline(
    cfg, st, tk, plan, topo)).lower(staged, toks)
off_low = jax.jit(lambda st, tk: pp.prefill_pipeline(
    cfg, st, tk, plan, topo, prefix_chunks=0, prefix_pool=None,
    return_kv=False)).lower(staged, toks)
assert base_low.as_text() == off_low.as_text(), "disarmed path diverged"

# 2) return_kv leaves the logits bit-identical and yields the final pool
base = np.asarray(jax.jit(lambda st, tk: pp.prefill_pipeline(
    cfg, st, tk, plan, topo))(staged, toks))
out, kv = jax.jit(lambda st, tk: pp.prefill_pipeline(
    cfg, st, tk, plan, topo, return_kv=True))(staged, toks)
assert np.array_equal(np.asarray(out), base), "return_kv changed logits"

# 3) seeded prefix run: GARBAGE tokens in the hit region + the cached pool
#    must reproduce the baseline logits bit-identically — the cached KV,
#    not the token stream, is authoritative for served chunks
k = 3
c = plan.chunk_len
toks_garb = toks.copy()
toks_garb[:, :k * c] = 7
pool = jax.tree.map(lambda a: np.asarray(a), kv)
f_led = jax.jit(lambda st, tk, pl: pp.prefill_pipeline(
    cfg, st, tk, plan, topo, prefix_chunks=k, prefix_pool=pl,
    return_ledger=True, return_telemetry=True, return_kv=True))
out2, led, tel, kv2 = f_led(staged, toks_garb, pool)
assert np.array_equal(np.asarray(out2), base), "seeded run not bit-identical"
assert np.asarray(kv2.k).shape == np.asarray(kv.k).shape

# 4) ledger + telemetry prefix_hit match the closed-form saved-bytes model
sb = obs_t.prefix_saved_model(plan, plan.layers_per_stage, b, c,
                              cfg.num_kv_heads, cfg.resolved_head_dim, k)
got = float(led["prefix_hit"])
assert abs(got - sb["ledger_bytes"]) < 1e-6 * max(sb["ledger_bytes"], 1), \
    (got, sb["ledger_bytes"])
ev = float(np.asarray(tel["prefix_hit"])[:, -1].sum())
assert ev == sb["events"], (ev, sb["events"])

# 5) the ARMED lowering adds ZERO collectives over the disarmed one
COLL = re.compile(r"collective-permute|collective_permute|all-reduce|"
                  r"all_reduce|all-gather|all_gather|reduce-scatter|"
                  r"reduce_scatter")
armed_low = jax.jit(lambda st, tk, pl: pp.prefill_pipeline(
    cfg, st, tk, plan, topo, prefix_chunks=k, prefix_pool=pl,
    return_kv=True)).lower(staged, toks_garb, pool)
n_off = len(COLL.findall(off_low.as_text()))
n_on = len(COLL.findall(armed_low.as_text()))
assert n_off > 0 and n_on == n_off, (n_off, n_on)
print("PASS", n_off)
"""


def test_device_prefix_parity_closed_form_and_zero_collectives():
    """Tentpole acceptance (device leg): a seeded prefix pool with garbage
    hit-region tokens is bit-identical to the baseline, the ledger and
    telemetry ``prefix_hit`` rows equal the closed-form saved-bytes model,
    the disarmed path lowers to byte-identical HLO, and arming the prefix
    path adds zero collectives."""
    _run(SNIPPET_DEVICE_PARITY)


SNIPPET_ENGINE_ROUND_TRIP = r"""
import os, dataclasses
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import get_smoke_config, RunConfig
from repro.core import costmodel as cm
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                  JaxExecutor, Request)

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype="float32")
n = m = 8; s = 128
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh, stage_axis="data", tp_axis="model")
run = RunConfig(num_chunks=m, num_stages=n, remote_attn="fetch")
plan = pp.build_plan(cfg, n, s, run)
model = build_model(cfg)
params = model.init(jax.random.key(0))
staged = pp.stage_params(cfg, params, plan)
compat.set_mesh(mesh)

rng = np.random.default_rng(1)
pref = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)  # 4 shared chunks
TOKS = []
for i in range(3):
    t = rng.integers(0, cfg.vocab_size, s).astype(np.int32)
    t[:64] = pref
    TOKS.append(t)

def run_engine(mode):
    ec = EngineConfig(model=cfg, hw=cm.TPU_V5E, num_stages=n, tp=1,
                     num_chunks=m, max_batch=1, buckets=(s,),
                     partition="uniform", prefix_cache=mode)
    ex = JaxExecutor(cfg, staged, topo, run)
    eng = ContinuousEngine(ec, ex)
    for i, t in enumerate(TOKS):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=s, tokens=t.copy()))
    eng.run_until_drained()
    return eng, ex

eng_off, ex_off = run_engine("off")
eng_on, ex_on = run_engine("on")
# off: no wave ever arms the device prefix path
assert all(w["prefix_chunks"] == 0 for w in ex_off.waves)
# on: the first wave is cold, every later wave seeds its 4 shared chunks
ks = [w["prefix_chunks"] for w in ex_on.waves]
assert ks[0] == 0 and all(k > 0 for k in ks[1:]), ks
# per-request logits identical regardless of serving path
res_off = {r.rid: np.asarray(r.result) for r in eng_off.done}
res_on = {r.rid: np.asarray(r.result) for r in eng_on.done}
assert set(res_off) == set(res_on) == {0, 1, 2}
for rid in res_off:
    assert np.array_equal(res_off[rid], res_on[rid]), f"rid {rid} diverged"
st = eng_on.prefix_stats()
assert st["prefix_hits"] == 2, st
print("PASS", ks)
"""


def test_jax_engine_prefix_round_trip_bit_identical():
    """JaxExecutor end-to-end: the engine hashes submitted tokens, the
    DeviceSeedCache serves later matching requests a seeded pool, and every
    request's logits are bit-identical to the prefix-off run."""
    _run(SNIPPET_ENGINE_ROUND_TRIP)
