"""KV page store tests (repro.kvstore): codec round-trip error bounds per
config family, page-table collision-freedom over a full MBKR steady-state
cycle, quantized-byte lease accounting under mixed-bucket admission, the
attention-output error bound for int8 pages (both backends, p99 <= the
deep-int8 tolerance), tier planning / cold staging, and the end-to-end
pipeline parity run with quantized pages."""
import math

import numpy as np
import pytest

from tests.helpers.subproc import run_pipeline_check

DEEP_INT8_P99_TOL = 0.05   # the historical deep-int8 spill tolerance


# ------------------------------------------------------- codec round trips

# (family, kv tensor shape [lps, B, C, kvh, hd]) — per config family so
# head-count/head-dim geometry differences are exercised
FAMILY_SHAPES = [
    ("dense-qwen3-8b", (2, 2, 32, 4, 64)),
    ("moe-qwen2", (2, 1, 16, 2, 32)),
    ("hybrid-zamba2", (1, 2, 16, 8, 40)),      # non-lane head dim
    ("encdec-whisper", (2, 1, 64, 6, 48)),
]

@pytest.mark.parametrize("dtype", ["int8", "fp8"])
@pytest.mark.parametrize("family,shape", FAMILY_SHAPES)
def test_codec_round_trip_bounds(dtype, family, shape):
    """Round-trip error against the CODEC's own bound, elementwise:

    - int8: round-to-nearest on a per-page per-head grid of step ``scale``
      => |err| <= scale / 2 everywhere (exact by construction);
    - fp8-e4m3: payloads live in [0, 448] with ulp <= 32 at the top bin
      => |err| <= 32 * scale = amax / 14.

    Plus a signal-level check: RMS error stays under 1% of the per-head
    amax for both codecs (what attention accuracy actually depends on).
    """
    import jax
    import jax.numpy as jnp
    from repro.kvstore import quant as Q
    codec = Q.get_codec(dtype)
    kv = jax.random.normal(jax.random.key(hash(family) % 2**31), shape,
                           jnp.float32)
    pages = 4 if shape[2] % 4 == 0 else 1
    payload, scale = Q.encode(codec, kv, pages=pages)
    assert str(payload.dtype) == codec.storage_dtype
    scale_tok = np.asarray(Q.expand_page_scale(scale, shape[2] // pages))
    back = np.asarray(Q.decode(payload, Q.expand_page_scale(
        scale, shape[2] // pages)))
    err = np.abs(back - np.asarray(kv))
    step = scale_tok * (0.5 if dtype == "int8" else 32.0)
    assert (err <= step * (1 + 1e-5)).all(), f"{family}/{dtype}"
    amax = scale_tok * (127.0 if dtype == "int8" else 448.0)
    rms = np.sqrt(np.mean((err / amax) ** 2))
    assert rms < 0.01, f"{family}/{dtype}: rms/amax {rms}"


def test_codec_auto_is_identity():
    import jax
    import jax.numpy as jnp
    from repro.kvstore import quant as Q
    codec = Q.get_codec("auto", "bfloat16")
    assert codec.name == "bfloat16" and not codec.quantized
    kv = jax.random.normal(jax.random.key(0), (2, 4, 2, 8), jnp.bfloat16)
    payload, scale = Q.encode(codec, kv)
    assert scale is None
    assert (np.asarray(payload, np.float32)
            == np.asarray(kv, np.float32)).all()


def test_pages_scatter_gather_round_trip():
    import jax
    import jax.numpy as jnp
    from repro.kvstore import pages as PG
    from repro.kvstore import quant as Q
    geom = PG.page_geometry(16, 5, kv_page_tokens=4)
    tbl = PG.build_slot_pages(geom)
    codec = Q.get_codec("int8")
    pool = PG.alloc_pool(geom, codec, lps=2, b=1, kvh=3, hd=8)
    k = jax.random.normal(jax.random.key(1), (2, 1, 16, 3, 8))
    v = jax.random.normal(jax.random.key(2), (2, 1, 16, 3, 8))
    pool = PG.scatter_chunk(pool, jnp.asarray(tbl[2]), k, v, codec)
    for li in range(2):
        sl = lambda a: a[:, li]
        kq, vq, ks, vs = PG.gather_chunk(sl(pool.k), sl(pool.v),
                                         sl(pool.k_scale), sl(pool.v_scale),
                                         jnp.asarray(tbl[2]))
        scale_tok = np.asarray(Q.expand_page_scale(ks, geom.page_tokens))
        kd = np.asarray(Q.decode(kq, Q.expand_page_scale(ks, geom.page_tokens)))
        err = np.abs(kd - np.asarray(k[li]))
        assert (err <= scale_tok * 0.5 * (1 + 1e-5)).all()


# ------------------------------------ page-table collision freedom (MBKR)

@pytest.mark.parametrize("m,n", [(16, 16), (16, 8), (8, 8), (24, 16), (12, 4)])
@pytest.mark.parametrize("ppc_tokens", [0, 4])
def test_page_table_collision_free_steady_state(m, n, ppc_tokens):
    """Replay the MBKR back-to-back steady state at PAGE granularity on a
    (stage, pair) couple: no live page is ever overwritten, and every
    pool-scan read finds all of its chunk's pages. This is the page-level
    analogue of ``mbkr.verify_plan`` — the slot plan's collision-freedom
    must survive the slot->page indirection."""
    from repro.core import mbkr
    from repro.kvstore import pages as PG
    pl = mbkr.plan(m, n)
    mbkr.verify_plan(pl)                      # slot level (precondition)
    chunk_len = 16
    geom = PG.page_geometry(chunk_len, pl.num_slots, ppc_tokens)
    tbl = PG.build_slot_pages(geom)
    PG.verify_page_plan(tbl, geom)            # handles are a bijection
    if pl.p2 >= m:
        return                                # no spilling: trivial buffer

    n2 = n // 2
    # page pools of me (stage 0) and my pair (stage n2):
    # page id -> (owner, req, chunk, death_tick)
    pools = {0: {}, 1: {}}
    stage_of = {0: 0, 1: n2}
    host_table = {0: pl.host_slot_a, 1: pl.host_slot_b}

    def phase(me, t):
        tt = t - stage_of[me]
        return tt % m, tt // m

    def write(pool, pages, entry, t):
        for pid in pages:
            prev = pool.get(int(pid))
            assert prev is None or prev[3] < t, \
                ("live page overwritten", t, pid, prev, entry)
            pool[int(pid)] = entry

    for t in range(n2, 4 * m + n2):
        for me in (0, 1):
            phi, req = phase(me, t)
            if req < 0:
                continue
            other = 1 - me
            death = t + (m - 1 - phi)
            if phi < pl.p2:
                write(pools[me], tbl[int(pl.own_slot[phi])],
                      (me, req, phi, death), t)
            else:
                write(pools[other], tbl[int(host_table[other][phi])],
                      (me, req, phi, death), t)
        for me in (0, 1):
            phi, req = phase(me, t)
            if req < 1:
                continue
            other = 1 - me
            for j in range(phi + 1):
                if j < pl.p2:
                    pages = tbl[int(pl.own_slot[j])]
                    pool = pools[me]
                else:
                    pages = tbl[int(host_table[other][j])]
                    pool = pools[other]
                for pid in pages:
                    e = pool.get(int(pid))
                    assert e and e[:3] == (me, req, j), \
                        ("page miss", t, me, j, pid, e)


# --------------------------------------- quantized-byte lease accounting

def _continuous(kv_dtype, buckets=(16384, 65536), inflight=2):
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.runtime.engine import ContinuousEngine, EngineConfig, SimExecutor
    cfg = get_config("llama3-70b")
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                      num_chunks=16, max_batch=8, buckets=buckets,
                      partition="uniform", kv_dtype=kv_dtype,
                      inflight=inflight)
    return ContinuousEngine(ec, SimExecutor(cfg, ec.hw))


def test_lease_hwm_within_budget_quantized_mixed_buckets():
    """hwm <= budget must hold with int8 byte accounting under mixed-bucket
    admission, and the quantized high-water mark must sit near the codec's
    compression factor of the bf16 one (leases count STORED bytes)."""
    from repro.runtime.engine import Request
    hwms = {}
    for kv_dtype in ("auto", "int8"):
        eng = _continuous(kv_dtype)
        for i in range(12):
            eng.submit(Request(rid=i, arrival=0.0,
                               seq_len=16384 if i % 3 else 65536))
        eng.run_until_drained()
        assert eng.metrics()["completed"] == 12
        assert (eng.lease.hwm <= eng.lease.budget * (1 + 1e-9)).all(), kv_dtype
        hwms[kv_dtype] = eng.lease.hwm.max()
    # int8 stored bytes ~ 0.5x bf16 (+ per-page scale overhead)
    ratio = hwms["int8"] / hwms["auto"]
    assert 0.45 < ratio < 0.60, ratio


def test_quantized_leases_admit_what_bf16_cannot():
    """Admission capacity grows with the codec: at a budget of ONE request's
    worth of MBKR slots (inflight=1, 12 slots vs a 16-chunk peak residency),
    bf16 requests cannot be admitted at all, while int8 accounting (~0.52x
    stored bytes) fits every one of them under the SAME physical budget."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                      Request, SimExecutor)
    cfg = get_config("llama3-70b")
    done, refusals = {}, {}
    for kv_dtype in ("auto", "int8"):
        ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=16, tp=1,
                          num_chunks=16, max_batch=8, buckets=(131072,),
                          partition="uniform", kv_dtype=kv_dtype, inflight=1)
        eng = ContinuousEngine(ec, SimExecutor(cfg, ec.hw))
        for i in range(10):
            eng.submit(Request(rid=i, arrival=0.0, seq_len=131072))
        eng.run_until_drained()
        assert (eng.lease.hwm <= eng.lease.budget * (1 + 1e-9)).all()
        done[kv_dtype] = eng.metrics()["completed"]
        refusals[kv_dtype] = eng.lease.refusals
    assert done["auto"] == 0 and refusals["auto"] == 10, (done, refusals)
    assert done["int8"] == 10 and refusals["int8"] == 0, (done, refusals)


# --------------------------- attention-output error bound (both backends)

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_int8_attention_output_p99_within_deep_tolerance(backend):
    """The acceptance bound: int8-paged attention OUTPUT error (one full
    pool-scan + self block composite, either backend) stays at p99 <= the
    deep-int8 tolerance, against the fp32 unquantized reference."""
    import jax
    import jax.numpy as jnp
    from repro.core import attention as A
    from repro.kvstore import pages as PG
    from repro.kvstore import quant as Q
    b, c, kvh, g, d, nchunks = 2, 32, 2, 3, 64, 5
    geom = PG.page_geometry(c, nchunks, kv_page_tokens=8)
    tbl = PG.build_slot_pages(geom)
    codec = Q.get_codec("int8")
    ks = jax.random.split(jax.random.key(7), 2 * nchunks + 3)
    qg = jax.random.normal(ks[0], (b, c, kvh, g, d), jnp.float32)
    k_self = jax.random.normal(ks[1], (b, c, kvh, d), jnp.float32)
    v_self = jax.random.normal(ks[2], (b, c, kvh, d), jnp.float32)
    scale = 1.0 / math.sqrt(d)

    pool = PG.alloc_pool(geom, codec, lps=1, b=b, kvh=kvh, hd=d)
    chunks = []
    for j in range(nchunks):
        kj = jax.random.normal(ks[3 + 2 * j], (1, b, c, kvh, d), jnp.float32)
        vj = jax.random.normal(ks[4 + 2 * j], (1, b, c, kvh, d), jnp.float32)
        chunks.append((kj[0], vj[0]))
        pool = PG.scatter_chunk(pool, jnp.asarray(tbl[j]), kj, vj, codec)

    be = A.get_backend(backend)
    sl = lambda a: a[:, 0]
    pool_l = (sl(pool.k), sl(pool.v), sl(pool.k_scale), sl(pool.v_scale))
    slot_chunk = np.concatenate([np.arange(nchunks), [-1]]).astype(np.int32)
    st = A.attn_init(b, c, kvh, g, d)
    st = A.pool_scan(be, qg, pool_l, tbl, slot_chunk, jnp.int32(nchunks),
                     scale, st)
    st = be.self_block(qg, k_self, v_self, scale, st)
    out = np.asarray(A.attn_finish(st, jnp.float32))

    ref_be = A.get_backend("jnp")
    st_r = A.attn_init(b, c, kvh, g, d)
    for j, (kj, vj) in enumerate(chunks):
        st_r = ref_be.chunk_block(qg, kj, vj, jnp.bool_(True), scale, st_r)
    st_r = ref_be.self_block(qg, k_self, v_self, scale, st_r)
    ref = np.asarray(A.attn_finish(st_r, jnp.float32))

    # normalize by the output's signal level: attention outputs of random
    # KV center on zero, so elementwise relative error is ill-posed there
    err_p99 = float(np.percentile(np.abs(out - ref), 99))
    rms = float(np.sqrt(np.mean(ref ** 2)))
    assert err_p99 / rms <= DEEP_INT8_P99_TOL, \
        f"{backend}: p99/rms {err_p99 / rms}"
    assert np.isfinite(out).all()


# --------------------------------------------------- tiers / cold staging

def test_tier_plan_prefetch_feasibility():
    from repro.core import mbkr
    from repro.kvstore import pages as PG
    from repro.kvstore import quant as Q
    from repro.kvstore import tiers as TR
    m, n = 16, 16
    pl = mbkr.plan(m, n)
    geom = PG.page_geometry(128, pl.num_slots, 32)
    tbl = PG.build_slot_pages(geom)
    codec = Q.get_codec("int8")
    dims = dict(lps=4, b=1, kvh=8, hd=128)
    cb = TR.chunk_page_bytes(geom, codec, **dims)
    # hot budget for half the own chunks -> the rest go cold
    spec = TR.TierSpec(hot_bytes=cb * pl.p2 / 2, cold_bw=1e12)
    plan = TR.plan_tiers(geom, codec, tbl, pl.own_slot, pl.p2, m, spec,
                         **dims, tick_s=np.full(m, 1e-3))
    assert plan.feasible
    assert plan.cold_bytes > 0 and plan.hot_bytes <= spec.hot_bytes * (1 + 1e-9)
    # every cold page must be prefetched BEFORE its due tick
    assert all(op.issue_tick < op.due_tick for op in plan.prefetch)
    # starving the staging link must flip feasibility
    slow = TR.plan_tiers(geom, codec, tbl, pl.own_slot, pl.p2, m,
                         TR.TierSpec(hot_bytes=spec.hot_bytes, cold_bw=1.0),
                         **dims, tick_s=np.full(m, 1e-3))
    assert not slow.feasible


def test_max_seq_len_int8_vs_bf16_ratio():
    """Equal per-stage byte budget: int8 pages must admit >= 1.5x the bf16
    max feasible sequence length (the benchmark's acceptance floor)."""
    from repro.kvstore import quant as Q
    from repro.kvstore import tiers as TR
    kw = dict(kv_token_bytes=4096.0, num_chunks=16, num_stages=16,
              page_tokens=64, head_dim=128)
    s_bf16 = TR.max_seq_len_for_budget(1e9, codec=Q.get_codec("bfloat16"), **kw)
    s_int8 = TR.max_seq_len_for_budget(1e9, codec=Q.get_codec("int8"), **kw)
    assert s_int8 >= 1.5 * s_bf16, (s_int8, s_bf16)


def test_host_offload_stager_round_trip():
    import jax
    import jax.numpy as jnp
    from repro.kvstore.tiers import HostOffloadStager
    pages = jax.random.normal(jax.random.key(0), (8, 2, 4, 2, 8))
    ref = np.asarray(pages)
    st = HostOffloadStager()
    parked = st.offload("k", pages, [1, 5, 6])
    assert st.host_bytes() > 0
    assert (np.asarray(parked)[[1, 5, 6]] == 0).all()       # cleared on device
    assert (np.asarray(parked)[[0, 2, 3, 4, 7]] == ref[[0, 2, 3, 4, 7]]).all()
    back = st.restore("k", parked)
    np.testing.assert_array_equal(np.asarray(back), ref)
    assert st.host_bytes() == 0


# ------------------------------------------------ end-to-end (subprocess)

def test_pipeline_int8_pages_backend_parity():
    """Deep pipeline, int8 KV pages, 4-token pages, both backends: jnp and
    pallas read the SAME quantized pages and must agree; end-to-end logits
    stay within the documented int8 tail bounds and the argmax matches."""
    run_pipeline_check("qwen3-8b", "mocap", "qship", deep=True,
                       backend="both", kv_dtype="int8", page_tokens=4,
                       expect="PASS backend-parity")


def test_pipeline_fp8_pages():
    run_pipeline_check("qwen3-8b", "mocap", "fetch", deep=True,
                       backend="jnp", kv_dtype="fp8", page_tokens=8)
