"""Training substrate: AdamW correctness, grad-accum equivalence, LR
schedule, loss decreases on the synthetic stream."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config, replace
from repro.data import SyntheticLM
from repro.models.api import build_model
from repro.train import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.step import init_train_state, make_train_step


def test_adamw_matches_reference():
    """One step vs a transparent numpy AdamW."""
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      grad_clip=1e9, warmup_steps=0, total_steps=10,
                      min_lr_frac=1.0)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]]), "b": jnp.array([0.1, 0.2])}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]]), "b": jnp.array([0.5, -0.5])}
    st = adamw_init(p)
    p2, st2, _ = adamw_update(cfg, g, st, p)
    # reference
    for k, nd in (("w", 2), ("b", 1)):
        gr = np.asarray(g[k])
        m = 0.1 * gr
        v = 0.01 * gr * gr
        mh, vh = m / (1 - 0.9), v / (1 - 0.99)
        upd = mh / (np.sqrt(vh) + 1e-8)
        if nd > 1:
            upd = upd + 0.1 * np.asarray(p[k])
        want = np.asarray(p[k]) - 1e-2 * upd
        np.testing.assert_allclose(np.asarray(p2[k]), want, rtol=1e-6)
    assert int(st2["step"]) == 1


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=0.1, warmup_steps=0, total_steps=10)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = adamw_init(p)
    _, _, metrics = adamw_update(cfg, g, st, p)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) == pytest.approx(0.1)
    assert float(cosine_lr(cfg, jnp.int32(9))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.int32(110))) == pytest.approx(0.1)
    mid = float(cosine_lr(cfg, jnp.int32(60)))
    assert 0.5 < mid < 0.6


def test_grad_accum_equivalence():
    cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=5)
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    opt = AdamWConfig(warmup_steps=0, total_steps=10)
    s1, m1 = jax.jit(make_train_step(model, None, opt, grad_accum=1,
                                     remat=False))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, None, opt, grad_accum=4,
                                     remat=False))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_loss_decreases_end_to_end():
    cfg = replace(get_smoke_config("stablelm-3b"), dtype="float32")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(1))
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=2)
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40)
    step = jax.jit(make_train_step(model, None, opt, remat=False))
    losses = []
    for _ in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
    assert all(np.isfinite(losses))


def test_remat_matches_no_remat():
    cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=7)
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    opt = AdamWConfig(warmup_steps=0, total_steps=10)
    _, m1 = jax.jit(make_train_step(model, None, opt, remat=False))(state, batch)
    _, m2 = jax.jit(make_train_step(model, None, opt, remat=True))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)
