"""Chunked-pipeline equivalence vs the full-forward oracle, run in
subprocesses with 8 fake host devices (the main pytest process keeps the real
single device — see conftest)."""
import pytest

from tests.helpers.subproc import run_pipeline_check as _run


CASES = [
    # the paper technique, both remote-attention modes
    ("qwen3-8b", "mocap", "qship"),
    ("qwen3-8b", "mocap", "fetch"),
    # baselines
    ("qwen3-8b", "terapipe", "qship"),
    ("qwen3-8b", "gpipe", "qship"),
    # families
    ("granite-3-2b", "mocap", "qship"),         # granite scalars
    ("qwen2-moe-a2.7b", "mocap", "qship"),      # MoE + shared experts
    ("granite-moe-3b-a800m", "mocap", "fetch"),
    ("mamba2-130m", "terapipe", "qship"),       # attn-free (MBKR inapplicable)
    ("zamba2-7b", "mocap", "qship"),            # hybrid, shared attn block
    ("zamba2-7b", "mocap", "fetch"),
    ("whisper-small", "mocap", "qship"),        # enc-dec with cross-attention
    ("llava-next-34b", "mocap", "qship"),       # VLM embed splice (unaligned)
    ("stablelm-3b", "terapipe", "fetch"),
]


@pytest.mark.parametrize("arch,mode,remote", CASES)
def test_pipeline_equivalence(arch, mode, remote):
    _run(arch, mode, remote)


def test_pipeline_int8_spill_compression():
    """Beyond-paper int8 KV-spill: bounded quantization error."""
    _run("qwen3-8b", "mocap", "qship", "int8")


@pytest.mark.parametrize("arch,remote,spill", [
    ("qwen3-8b", "qship", "bfloat16"),
    ("qwen3-8b", "fetch", "bfloat16"),
    ("qwen3-8b", "qship", "int8"),
    ("zamba2-7b", "qship", "bfloat16"),
])
def test_pipeline_deep_remote_values(arch, remote, spill):
    """8 stages -> p2 < M-1: REMOTE chunks are actually consumed by later
    chunks' attention — validates fetch/qship VALUES and the int8 wire
    (shallow configs only validate their masking)."""
    _run(arch, "mocap", remote, spill, deep=True)


def test_build_plan_terapipe_pool_is_M():
    from repro.configs.base import RunConfig, get_smoke_config
    from repro.core import pipeline as pp
    cfg = get_smoke_config("qwen3-8b")
    plan = pp.build_plan(cfg, 4, 128, RunConfig(num_chunks=8, num_stages=4),
                         mode="terapipe")
    assert plan.num_slots == 8 and plan.p2 == 8


def test_build_plan_mocap_pool_smaller():
    from repro.configs.base import RunConfig, get_smoke_config
    from repro.core import pipeline as pp
    cfg = get_smoke_config("qwen3-8b")
    plan = pp.build_plan(cfg, 4, 128, RunConfig(num_chunks=8, num_stages=4),
                         mode="mocap")
    assert plan.num_slots < 8, "MBKR must shrink the KV pool"
    assert plan.p2 < 8


def test_stage_params_roundtrip_shapes():
    import jax
    from repro.configs.base import RunConfig, get_smoke_config
    from repro.core import pipeline as pp
    from repro.models.api import build_model
    cfg = get_smoke_config("qwen3-8b")  # 2 layers -> N=4 stages pads to 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    plan = pp.build_plan(cfg, 4, 128, RunConfig(num_chunks=8, num_stages=4))
    staged = pp.stage_params(cfg, params, plan)
    wq = staged["stage_layers"]["wq"]
    assert wq.shape[0] == 4 and wq.shape[1] == plan.layers_per_stage
    # stages beyond the real layers are exact zero (residual identity)
    import numpy as np
    n_real = cfg.num_layers  # 2 layers over 4 stages, lps=1
    assert np.abs(np.asarray(wq))[n_real:].sum() == 0.0
    assert np.abs(np.asarray(wq))[:n_real].sum() > 0.0
