"""repro.obs tests (ISSUE 6): the device StageTelemetry profile matches the
analytic occupancy/byte models and the CollectiveLedger, the disabled path
is bit-identical with zero extra collectives, the merged Perfetto trace
carries every surface in one file, the metrics exporters produce valid
JSON-lines/Prometheus output atomically, and ``count_launches`` nests with
per-kernel attribution."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(snippet, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout, r.stdout
    return r.stdout


# --------------------------------------------------- analytic occupancy model

def test_analytic_occupancy_mbkr_vs_terapipe():
    """The Fig-1-style imbalance: MBKR's live slot peak is p2 (< m) on every
    stage, terapipe's is m — the cross-half pairing flattens residency."""
    from repro.core import mbkr
    from repro.obs import telemetry as obs_t
    for m, n in ((8, 8), (16, 16)):
        plan = mbkr.plan(m, n)
        own, hosted = obs_t.analytic_occupancy(m, n, plan.p2)
        occ = own + hosted
        assert occ.shape == (n, m + n - 1)
        assert int(occ.max()) == plan.num_slots  # peak == provisioned slots
        assert (occ.max(axis=1) == plan.p2).all()  # every stage, same peak
        own_t, hosted_t = obs_t.analytic_occupancy(m, n, m, mode="terapipe")
        assert (hosted_t == 0).all()  # no hosting without MBKR
        assert int((own_t + hosted_t).max()) == m  # full pool on every stage
        assert occ.max() < (own_t + hosted_t).max()


def test_occupancy_model_record():
    from repro.configs.base import RunConfig, get_smoke_config
    from repro.core import pipeline as pp
    from repro.obs.telemetry import occupancy_model
    cfg = get_smoke_config("qwen3-8b")
    plan = pp.build_plan(cfg, 8, 128, RunConfig(num_chunks=8, num_stages=8))
    om = occupancy_model(plan)
    assert om["stages"] == 8 and om["ticks"] == 15
    assert om["peak_slots"] == om["num_slots"] == plan.num_slots
    assert len(om["table"]) == 8 and len(om["table"][0]) == 15
    assert max(max(row) for row in om["table"]) == plan.num_slots


def test_chunk_stored_bytes_matches_kvlease_accounting():
    """The device-side KV-byte price and the scheduler's lease accounting
    (costmodel.kv_chunk_bytes x kvstore.kv_compress_factor) are the SAME
    number — one chunk is priced identically by both bookkeepers."""
    from repro.configs.base import RunConfig, get_config
    from repro.core import costmodel as cm
    from repro.core import pipeline as pp
    from repro.kvstore import quant as kvq
    from repro.obs.telemetry import chunk_stored_bytes
    cfg = get_config("qwen3-8b")
    n, m, s = 8, 8, 4096
    c = s // m
    for kv_dtype, page_tokens in (("auto", 0), ("int8", 0), ("int8", 128),
                                  ("fp8", 256)):
        run = RunConfig(num_chunks=m, num_stages=n, kv_dtype=kv_dtype,
                        kv_page_tokens=page_tokens)
        plan = pp.build_plan(cfg, n, s, run)
        lps = plan.layers_per_stage
        dev = chunk_stored_bytes(plan, lps, 1, c, cfg.num_kv_heads,
                                 cfg.resolved_head_dim)
        sm = cm.StageModel.build(cfg, n, 1)
        sched = cm.kv_chunk_bytes(sm, c) * kvq.kv_compress_factor(
            plan.codec, model_dtype=cfg.dtype,
            page_tokens=page_tokens or c, head_dim=cfg.resolved_head_dim)
        assert np.isclose(dev, sched, rtol=1e-9), (kv_dtype, dev, sched)


def test_skew_all_empty_key_is_zero():
    """Regression (ISSUE 8 satellite): ``skew`` on an all-empty key — every
    per-stage peak 0, e.g. ``kv_bytes`` on an attention-free run — must
    return 0.0, not divide by zero (it previously returned nan and poisoned
    downstream comparisons)."""
    from repro.obs.telemetry import TelemetryProfile, safe_ratio
    zeros = np.zeros((4, 7))
    prof = TelemetryProfile({"own_chunks": zeros, "hosted_chunks": zeros,
                             "kv_bytes": zeros})
    assert prof.skew("kv_bytes") == 0.0
    assert prof.skew() == 0.0
    # nonzero keys keep the (max - min) / max definition
    kv = np.zeros((4, 7))
    kv[0, :] = 4.0
    kv[1:, :] = 1.0
    prof2 = TelemetryProfile({"own_chunks": zeros, "hosted_chunks": zeros,
                              "kv_bytes": kv})
    assert prof2.skew("kv_bytes") == pytest.approx((4.0 - 1.0) / 4.0)
    # the underlying helper: 0/0 -> 0.0, x/0 -> 0.0, normal division intact
    assert safe_ratio(0.0, 0.0) == 0.0
    assert safe_ratio(3.0, 0.0) == 0.0
    assert safe_ratio(3.0, 4.0) == pytest.approx(0.75)


# ------------------------------------------------ device telemetry (8 chips)

SNIPPET_TELEMETRY = """
import numpy as np, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.core import transport as tx
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.obs import telemetry as obs_t

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
c = s // m
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

run = RunConfig(num_chunks=m, num_stages=n, remote_attn="fetch")
plan = pp.build_plan(cfg, n, s, run)
staged = pp.stage_params(cfg, params, plan)
with compat.set_mesh(mesh):
    logits, led, tel = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo, return_ledger=True,
        return_telemetry=True))(staged, toks)
    logits0 = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo))(staged, toks)
led = tx.ledger_to_dict(led)
prof = obs_t.TelemetryProfile.from_run(tel)
assert prof.data["own_chunks"].shape == (n, m + n - 1)

# 1) occupancy == the analytic MBKR residency model, tick by tick
own, hosted = obs_t.analytic_occupancy(m, n, plan.p2)
assert np.allclose(prof.data["own_chunks"], own)
assert np.allclose(prof.data["hosted_chunks"], hosted)
assert prof.peak() == plan.num_slots

# 2) resident KV bytes == occupancy x the quantized chunk price
cb = obs_t.chunk_stored_bytes(plan, plan.layers_per_stage, b, c,
                              cfg.num_kv_heads, cfg.resolved_head_dim)
assert np.allclose(prof.data["kv_bytes"], (own + hosted) * cb)

# 3) event counts x analytic per-event price == the CollectiveLedger
pe = obs_t.per_event_wire_bytes(plan, cfg, b)
tot = prof.totals()
assert tot["spill_events"] == n * (m - plan.p2)
assert np.isclose(tot["spill_events"] * pe["spill"], led["spill"], rtol=1e-5)
assert np.isclose(tot["fetch_events"] * pe["fetch"], led["fetch"], rtol=1e-5)
assert tot["qship_events"] == 0.0 and tot["attn_work"] > 0
assert tot["launches"] > 0

# 4) the disabled path is bit-identical
assert (np.asarray(logits) == np.asarray(logits0)).all()

# 5) terapipe shows the paper's imbalance: full-pool peak m vs MBKR's p2
plan_t = pp.build_plan(cfg, n, s, run, mode="terapipe")
staged_t = pp.stage_params(cfg, params, plan_t)
with compat.set_mesh(mesh):
    _, tel_t = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan_t, topo, return_telemetry=True))(staged_t, toks)
prof_t = obs_t.TelemetryProfile.from_run(tel_t)
own_t, hosted_t = obs_t.analytic_occupancy(m, n, plan_t.p2, mode=plan_t.mode)
assert np.allclose(prof_t.data["own_chunks"], own_t)
assert np.allclose(prof_t.data["hosted_chunks"], hosted_t)
assert prof_t.peak() == m and prof.peak() == plan.p2 < m
print("PASS")
"""


def test_device_telemetry_matches_models():
    """Tentpole acceptance: the per-(stage, tick) device counters reproduce
    the analytic MBKR occupancy, the kvstore byte pricing, the ledger's
    wire categories, AND the MBKR-vs-terapipe imbalance — while the
    telemetry-off path returns bit-identical logits."""
    _run(SNIPPET_TELEMETRY)


SNIPPET_ZERO_COST = """
import re, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
plan = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n))
staged = pp.stage_params(cfg, params, plan)

COLL = re.compile(r"collective-permute|collective_permute|all-reduce|"
                  r"all_reduce|all-gather|all_gather|reduce-scatter|"
                  r"reduce_scatter")
def collectives(telemetry):
    with compat.set_mesh(mesh):
        low = jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo,
            return_telemetry=telemetry)).lower(staged, toks)
    return len(COLL.findall(low.as_text()))

off, on = collectives(False), collectives(True)
assert off > 0  # the pipeline itself does communicate
# telemetry is carry-threaded local arithmetic: ZERO extra collectives
assert on == off, (off, on)
print("PASS", off)
"""


def test_telemetry_adds_zero_collectives():
    _run(SNIPPET_ZERO_COST)


# ------------------------------------------------------------- merged trace

def test_trace_recorder_merged_format(tmp_path):
    from repro.obs.trace import TraceRecorder
    rec = TraceRecorder(enabled=True)
    rec.task(rid=1, chunk=0, stage=2, start=0.5, finish=1.0)
    rec.mark(rid=1, kind="arrival", time=0.1)
    rec.span("wave0", pid="engine", tid=0, start=0.0, finish=2.0,
             cat="wave", args={"rids": [1]})
    rec.counter("kv_resident_bytes", pid=2, time=0.5, values={"w0": 42.0})
    rec.process_name("engine", "engine (wall clock)")
    evs = rec.chrome_trace()["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    task = next(e for e in by_ph["X"] if e["cat"] == "chunk")
    assert task["pid"] == 2 and task["tid"] == 1
    assert task["ts"] == 0.5e6 and task["dur"] == 0.5e6  # seconds -> us
    wave = next(e for e in by_ph["X"] if e["cat"] == "wave")
    assert wave["pid"] == "engine" and wave["args"]["rids"] == [1]
    (ctr,) = by_ph["C"]
    assert ctr["name"] == "kv_resident_bytes" and ctr["args"] == {"w0": 42.0}
    names = {e["pid"]: e["args"]["name"] for e in by_ph["M"]}
    assert names["engine"] == "engine (wall clock)"
    assert names[2] == "stage 2"  # default label for int pids
    # disabled recorder records nothing
    off = TraceRecorder(enabled=False)
    off.task(1, 0, 0, 0.0, 1.0)
    off.counter("x", pid=0, time=0.0, values={"v": 1})
    assert off.chrome_trace()["traceEvents"] == []
    # export is atomic: real content, no stray tmp siblings
    out = tmp_path / "nested" / "trace.json"
    path = rec.export(str(out))
    assert json.load(open(path))["traceEvents"]
    assert [p.name for p in out.parent.iterdir()] == ["trace.json"]


def test_sched_trace_shim():
    """sched.trace keeps re-exporting the recorder (old imports work)."""
    from repro.obs import trace as obs_trace
    from repro.sched import trace as sched_trace
    assert sched_trace.TraceRecorder is obs_trace.TraceRecorder
    assert sched_trace.TaskEvent is obs_trace.TaskEvent


def test_engine_merged_trace_sim(tmp_path):
    """One ContinuousEngine run -> ONE trace with scheduler task spans,
    lease/wire counter tracks and process metadata; exports are valid."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                      Request, SimExecutor)
    cfg = get_config("llama3-70b")
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=8, tp=1,
                      num_chunks=8, max_batch=4, buckets=(8192,),
                      partition="lbcp", sa_iters=4, policy="fcfs", trace=True)
    eng = ContinuousEngine(ec, SimExecutor(cfg, ec.hw))
    for i in range(4):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=8192))
    eng.run_until_drained()
    evs = eng.merged_trace().chrome_trace()["traceEvents"]
    assert any(e["ph"] == "X" and e.get("cat") == "chunk" for e in evs)
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"kv_lease_bytes", "wire_bytes"} <= counters
    # pure: a second build yields the same event count
    assert len(eng.merged_trace().chrome_trace()["traceEvents"]) == len(evs)
    paths = eng.export_obs(trace_out=str(tmp_path / "t.json"),
                           metrics_out=str(tmp_path / "m.prom"))
    assert json.load(open(paths["trace"]))["traceEvents"]
    prom = open(paths["metrics"]).read()
    assert "# TYPE repro_completed counter" in prom
    assert "# TYPE repro_ttft_seconds histogram" in prom


# ------------------------------------------------------------------ metrics

def test_metrics_registry_formats(tmp_path):
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    reg.counter("repro_done", "done").inc(3)
    reg.gauge("repro_depth", "queue depth").set(1.5)
    h = reg.histogram("repro_lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    # idempotent getters; kind conflicts are errors
    assert reg.counter("repro_done") is reg.counter("repro_done")
    with pytest.raises(TypeError):
        reg.gauge("repro_done")
    lines = [json.loads(s) for s in reg.to_jsonl().splitlines()]
    by_name = {r["name"]: r for r in lines}
    assert by_name["repro_done"]["value"] == 3.0
    assert by_name["repro_lat_seconds"]["count"] == 3
    assert by_name["repro_lat_seconds"]["sum"] == pytest.approx(5.55)
    prom = reg.to_prom()
    assert "# TYPE repro_done counter" in prom
    assert 'repro_lat_seconds_bucket{le="0.1"} 1' in prom
    assert 'repro_lat_seconds_bucket{le="1.0"} 2' in prom
    assert 'repro_lat_seconds_bucket{le="+Inf"} 3' in prom  # cumulative
    assert "repro_lat_seconds_count 3" in prom
    # extension picks the format
    jl = reg.export(str(tmp_path / "m.jsonl"))
    pm = reg.export(str(tmp_path / "m.prom"))
    assert json.loads(open(jl).readline())["name"]
    assert open(pm).read().startswith("# HELP")


def test_export_engine_metrics_records(tmp_path):
    from repro.obs.metrics import export_engine_metrics
    from repro.sched.metrics import RequestRecord
    recs = [RequestRecord(rid=0, arrival=0.0, seq_len=8, bucket=8,
                          admit=0.25, finish=1.0),
            RequestRecord(rid=1, arrival=0.0, seq_len=8, bucket=8,
                          rejected=True)]  # inf times must not poison sums
    path = export_engine_metrics(
        str(tmp_path / "m.jsonl"),
        {"completed": 1, "avg_ttft": 1.0, "policy": "fcfs"},
        records=recs, extra={"wall_seconds": 2.0})
    rows = {r["name"]: r for r in map(json.loads, open(path))}
    assert rows["repro_completed"]["kind"] == "counter"
    assert rows["repro_ttft_seconds"]["count"] == 1  # rejected row skipped
    assert rows["repro_ttft_seconds"]["sum"] == pytest.approx(1.0)
    assert rows["repro_queue_wait_seconds"]["sum"] == pytest.approx(0.25)
    assert rows["repro_wall_seconds"]["value"] == 2.0
    assert "repro_policy" not in rows  # non-numeric summary entries skipped


def test_atomic_write(tmp_path):
    from repro.obs._io import atomic_write_text
    out = tmp_path / "a" / "b.txt"
    atomic_write_text(str(out), "one")
    atomic_write_text(str(out), "two")  # atomic replace, not append
    assert out.read_text() == "two"
    assert [p.name for p in out.parent.iterdir()] == ["b.txt"]


# ------------------------------------------------------------ kernel launches

def test_count_launches_nested_and_tagged():
    import jax
    from repro.kernels import ops
    q = np.zeros((1, 8, 2, 16), np.float32)
    k = np.zeros((1, 8, 2, 16), np.float32)

    def attend():
        ops.chunk_attention(jax.numpy.asarray(q), jax.numpy.asarray(k),
                            jax.numpy.asarray(k)).block_until_ready()

    with ops.count_launches() as outer:
        attend()
        with ops.count_launches() as inner:
            attend()
    assert inner["count"] == 1 and inner["chunk_attention"] == 1
    assert outer["count"] == 2 and outer["chunk_attention"] == 2
    assert "pool_attention" not in outer  # only tags that actually launched
    # the stack drained: launches outside any context cost nothing
    assert not ops._LAUNCH_FRAMES


# ----------------------------------------------------------- serve smoke

def test_serve_sim_metrics_smoke(tmp_path):
    """End-to-end exporter path: one sim serve run writes the merged trace
    and a Prometheus textfile via the CLI flags."""
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.prom"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--executor", "sim",
         "--scheduler", "continuous", "--requests", "4",
         "--trace-out", str(trace), "--metrics-out", str(metrics)],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "metrics ->" in r.stdout and "trace ->" in r.stdout
    evs = json.load(open(trace))["traceEvents"]
    assert any(e["ph"] == "C" for e in evs)
    assert "repro_completed 4.0" in open(metrics).read()
