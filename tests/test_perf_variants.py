"""Optimized-lowering variants (§Perf) stay bit-comparable to the oracle:
kv_split attention mesh, q-head padding, expert parallelism padding.

Under GSPMD these lowerings need auto-typed TP axes of size > 1 inside
shard_map, which old jaxlib cannot partition ("UNIMPLEMENTED:
PartitionId..."). ``build_plan`` resolves ``tp_lowering="auto"`` to the
MANUAL lowering there (explicit transport psums + manual expert
parallelism, DESIGN.md §3.6), so these tests now run — and the kv_split /
EP numerics hold — on BOTH jaxlib legs. The snippets print the resolved
lowering so CI logs show which path ran."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

SNIPPET_PAD_HEADS = r"""
import jax, jax.numpy as jnp
from repro import compat
from repro.compat import AxisType
from repro.configs.base import ModelConfig, RunConfig
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology

cfg = ModelConfig(arch="padtest", family="dense", num_layers=2, d_model=48,
                  num_heads=6, num_kv_heads=2, d_ff=96, vocab_size=128,
                  head_dim=8, dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (2, 64), 0, 128)
ref = model.forward(params, toks)[:, -1, :]
mesh = compat.make_mesh((2, 2, 2), ("data", "kv", "qg"),
                        axis_types=(AxisType.Auto,)*3)
topo = Topology(mesh=mesh, tp_axis=("kv", "qg"))
factors = pp.kv_split_axes(cfg, 4)
assert factors == (2, 2, 4), factors
cfg_pad, params_pad = pp.pad_q_heads(cfg, params, factors[2])
assert cfg_pad.num_heads == 8
plan = pp.build_plan(cfg_pad, 2, 64, RunConfig(num_chunks=8, num_stages=2))
staged = pp.stage_params(cfg_pad, params_pad, plan)
with compat.set_mesh(mesh):
    out = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg_pad, st, tk, plan, topo))(staged, toks)
err = float(jnp.max(jnp.abs(out - ref) / (jnp.abs(ref) + 1e-3)))
assert err < 2e-3, err
print("PASS", err)
"""

SNIPPET_EP = r"""
import jax, jax.numpy as jnp
from repro import compat
from repro.compat import AxisType
from repro.configs.base import ModelConfig, MoEConfig, RunConfig
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology

cfg = ModelConfig(arch="eptest", family="moe", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                  head_dim=8, dtype="float32",
                  moe=MoEConfig(num_experts=6, top_k=2, d_expert=64,
                                capacity_factor=8.0))
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (2, 64), 0, 128)
ref = model.forward(params, toks)[:, -1, :]
mesh = compat.make_mesh((2, 2, 2), ("data", "kv", "qg"),
                        axis_types=(AxisType.Auto,)*3)
topo = Topology(mesh=mesh, tp_axis=("kv", "qg"))
cfg2, params2 = pp.pad_experts(cfg, params, 8)
assert cfg2.moe.num_experts == 8 and cfg2.moe.real_experts == 6
plan = pp.build_plan(cfg2, 2, 64, RunConfig(num_chunks=8, num_stages=2))
staged = pp.stage_params(cfg2, params2, plan)
with compat.set_mesh(mesh):
    out = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg2, st, tk, plan, topo))(staged, toks)
err = float(jnp.max(jnp.abs(out - ref) / (jnp.abs(ref) + 1e-3)))
assert err < 2e-3, err
print("PASS", plan.tp_lowering, err)
"""


def _run(snippet):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout


def test_kv_split_with_head_padding():
    _run(SNIPPET_PAD_HEADS)


def test_expert_parallel_with_padding():
    _run(SNIPPET_EP)


def test_pad_experts_masks_router():
    import jax
    import jax.numpy as jnp
    from repro.models import layers as L
    # padded experts must never be selected even with favorable logits
    d, e = 8, 4
    params = {
        "router": jnp.ones((d, e)),            # pads have HIGH raw logits
        "wg": jnp.ones((e, d, 8)) * 0.1,
        "wu": jnp.ones((e, d, 8)) * 0.1,
        "wd": jnp.ones((e, 8, d)) * 0.1,
    }
    x = jnp.ones((1, 4, d))
    full = L.moe_layer(params, x, num_experts=e, top_k=2,
                       capacity_factor=8.0, num_real=2)
    only_real = L.moe_layer(
        {k: (v[:, :2] if k == "router" else v[:2]) for k, v in params.items()},
        x, num_experts=2, top_k=2, capacity_factor=8.0)
    assert jnp.allclose(full, only_real, atol=1e-6)
