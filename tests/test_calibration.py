"""Closed-loop profiling tests (ISSUE 8): the chunk-cost feature matrix is
an exact linear factorization of the analytic cost, ``obs.calibrate``
recovers a perturbed ground-truth profile from noiseless spans, the
calibrated-profile JSON round-trips bit-identically into ``plan_partition``
/ ``chunk_cost_arrays``, a mid-stream scheduler recalibration never reorders
admitted history, the measured-span replay returns bit-identical logits
with a telemetry-aligned ``MeasuredProfile``, and the health sentinels are
provably free when disarmed (zero extra collectives) and bit-identical when
armed."""
import os
import subprocess
import sys
from dataclasses import replace as dc_replace

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(snippet, extra_env=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", snippet], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "PASS" in r.stdout, r.stdout
    return r.stdout


def _true_hw():
    """The calibration benchmark's ground truth: datasheet rates off by
    -20% gemm, +10% attention, -10% HBM, -5% interconnect."""
    from repro.core import costmodel as cm
    return dc_replace(cm.WSC_PAPER, name="truth",
                      gemm_eff=cm.WSC_PAPER.gemm_eff * 0.8,
                      attn_eff=cm.WSC_PAPER.attn_eff * 1.1,
                      hbm_bw=cm.WSC_PAPER.hbm_bw * 0.9,
                      link_bw=cm.WSC_PAPER.link_bw * 0.95)


def _spans(sm, chunks, mplan, hw, n=16):
    """Noiseless [N, T] spans: chunk ph's cost under ``hw`` at every valid
    (stage, stage + ph)."""
    from repro.core import costmodel as cm
    cost = cm.chunk_cost_features(sm, chunks, cm.WSC_PAPER,
                                  mbkr_plan=mplan) @ cm.profile_theta(hw,
                                                                     sm.tp)
    m = len(chunks)
    tick_s = np.zeros((n, m + n - 1))
    for s in range(n):
        tick_s[s, s:s + m] = cost
    return tick_s


# ------------------------------------------------------- linear factorization

@pytest.mark.parametrize("arch", ["llama3-70b", "mamba2-130m"])
@pytest.mark.parametrize("use_mbkr", [True, False])
def test_chunk_cost_features_exact_identity(arch, use_mbkr):
    """``X @ profile_theta == dur + comm + spill_t + fetch_t`` EXACTLY —
    the linearity the least-squares fit inverts."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.core import mbkr
    cfg = get_config(arch)
    for tp in (1, 2):
        sm = cm.StageModel.build(cfg, 16, tp)
        chunks = [1024 + 256 * (i % 3) for i in range(16)]
        mplan = (mbkr.plan(16, 16)
                 if use_mbkr and not cfg.attn_free else None)
        feats = cm.chunk_cost_features(sm, chunks, cm.WSC_PAPER,
                                       mbkr_plan=mplan)
        dur, comm, _, spill_t, fetch_t = cm.chunk_cost_arrays(
            sm, chunks, cm.WSC_PAPER, mbkr_plan=mplan)
        assert feats.shape == (16, len(cm.FEATURE_TERMS))
        np.testing.assert_allclose(
            feats @ cm.profile_theta(cm.WSC_PAPER, tp),
            dur + comm + spill_t + fetch_t, rtol=1e-12)


def test_noiseless_fit_recovers_ground_truth():
    """Spans generated under a perturbed profile the fit never sees:
    nominal MAPE is a real gap (>1%), calibrated MAPE collapses to float
    noise, and the fitted profile reprices chunks like the ground truth."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.core import mbkr
    from repro.obs import calibrate as cal
    cfg = get_config("llama3-70b")
    sm = cm.StageModel.build(cfg, 16, 1)
    chunks = [2048] * 16
    mplan = mbkr.plan(16, 16)
    truth = _true_hw()
    fit = cal.fit_profile(sm, chunks, _spans(sm, chunks, mplan, truth),
                          cm.WSC_PAPER, mbkr_plan=mplan)
    assert fit.mape_nominal > 0.01
    assert fit.mape_calibrated < 1e-9
    assert np.abs(fit.residual_s).max() < 1e-9
    assert len(fit.rows) == 16 * 16          # every valid (stage, tick)
    def total(hw):
        dur, comm, _, sp, ft = cm.chunk_cost_arrays(sm, chunks, hw,
                                                    mbkr_plan=mplan)
        return dur + comm + sp + ft
    np.testing.assert_allclose(total(fit.profile), total(truth), rtol=1e-9)


# ----------------------------------------------------- persistence round-trip

def test_calibrated_profile_roundtrip_bit_identical(tmp_path):
    """save -> load -> the SAME HardwareProfile bit-for-bit, and
    ``plan_partition`` fed the JSON path reproduces the in-memory plan
    exactly (chunks AND objective) — json floats round-trip via repr."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.core import lbcp, mbkr
    from repro.obs import calibrate as cal
    cfg = get_config("llama3-70b")
    sm = cm.StageModel.build(cfg, 16, 1)
    chunks = [2048] * 16
    mplan = mbkr.plan(16, 16)
    fit = cal.fit_profile(sm, chunks, _spans(sm, chunks, mplan, _true_hw()),
                          cm.WSC_PAPER, mbkr_plan=mplan)
    path = str(tmp_path / "cal.json")
    cal.save_profile(path, fit.profile, fit=fit, meta={"src": "test"})
    loaded, blob = cal.load_profile(path)
    assert loaded == fit.profile             # dataclass eq: every field
    assert cm.resolve_profile(path) == fit.profile
    assert blob["fit"]["feature_terms"] == list(cm.FEATURE_TERMS)
    assert len(blob["fit"]["residuals"]) == len(fit.rows)
    kw = dict(sa_iters=8, sa_rounds=2, seed=3)
    mem = lbcp.plan_partition(cfg, 32768, 16, 16, fit.profile, **kw)
    disk = lbcp.plan_partition(cfg, 32768, 16, 16, path, **kw)
    assert disk.chunks == mem.chunks
    assert disk.dp_objective == mem.dp_objective
    assert disk.t_prefill == mem.t_prefill
    # and the calibrated plan actually differs from the nominal one's cost
    nom = lbcp.plan_partition(cfg, 32768, 16, 16, cm.WSC_PAPER, **kw)
    assert nom.t_prefill != pytest.approx(mem.t_prefill, rel=1e-6)


def test_resolve_profile_names_and_errors(tmp_path):
    from repro.core import costmodel as cm
    assert cm.resolve_profile(cm.WSC_PAPER) is cm.WSC_PAPER
    assert cm.resolve_profile("wsc-gr24") == cm.WSC_PAPER
    with pytest.raises((KeyError, ValueError, FileNotFoundError)):
        cm.resolve_profile("no-such-profile-or-file")


# ------------------------------------------------------- scheduler recalib

def test_scheduler_rebase_keeps_admitted_history():
    """Swapping nominal -> calibrated admission costs mid-stream leaves the
    already-admitted prefix untouched (same rids, same finish times) while
    future requests are priced with the new vectors."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.core import mbkr
    from repro.sched.scheduler import (ChunkPlan, ChunkScheduler,
                                       SchedRequest)
    cfg = get_config("llama3-70b")
    sm = cm.StageModel.build(cfg, 16, 1)
    mplan = mbkr.plan(16, 16)

    def plan_for(hw):
        def build(bucket):
            return ChunkPlan.build(bucket, [bucket // 16] * 16, sm, hw,
                                   mbkr_plan=mplan)
        return build

    sched = ChunkScheduler(16, plan_for(cm.WSC_PAPER), policy="sjf")
    for i in range(4):
        sched.submit(SchedRequest(rid=i, arrival=0.0, seq_len=32768,
                                  bucket=32768))
    sched.run()
    before = [(r.rid, r.admit_time, r.finish_time) for r in sched.admitted]
    assert len(before) == 4

    sched.rebase_costs(plan_for(_true_hw()))
    t1 = float(sched.stage_free.max()) + 1.0
    for i in range(4, 8):
        sched.submit(SchedRequest(rid=i, arrival=t1, seq_len=32768,
                                  bucket=32768))
    sched.run()
    after = [(r.rid, r.admit_time, r.finish_time) for r in sched.admitted]
    assert after[:4] == before               # history never reordered
    assert sorted(r[0] for r in after[4:]) == [4, 5, 6, 7]
    # the calibrated (slower-gemm) plan really is costlier per task
    assert (plan_for(_true_hw())(32768).work
            > plan_for(cm.WSC_PAPER)(32768).work)


def test_engine_recalibrate_swaps_costs_in_place():
    """ContinuousEngine.recalibrate(path) resolves the JSON, rebuilds the
    stage model/plan cache and rebases the scheduler — without dropping
    completed requests."""
    from repro.configs.base import get_config
    from repro.core import costmodel as cm
    from repro.obs import calibrate as cal
    from repro.runtime.engine import (ContinuousEngine, EngineConfig,
                                      Request, SimExecutor)
    import tempfile
    cfg = get_config("llama3-70b")
    ec = EngineConfig(model=cfg, hw=cm.WSC_PAPER, num_stages=8, tp=1,
                      num_chunks=8, max_batch=4, buckets=(8192,),
                      partition="lbcp", sa_iters=4)
    eng = ContinuousEngine(ec, SimExecutor(cfg, ec.hw))
    for i in range(2):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=8192))
    eng.run_until_drained()
    done_before = eng.metrics()["completed"]
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "cal.json")
        cal.save_profile(path, _true_hw())
        hw = eng.recalibrate(path)
    assert hw.name == "truth" and eng.ec.hw == hw
    for i in range(2, 4):
        eng.submit(Request(rid=i, arrival=0.0, seq_len=8192))
    eng.run_until_drained()
    assert eng.metrics()["completed"] == done_before + 2


# ------------------------------------------------- measured spans (8 chips)

SNIPPET_MEASURED = """
import numpy as np, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.obs.profile import measure_prefill

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
plan = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n))
staged = pp.stage_params(cfg, params, plan)

with compat.set_mesh(mesh):
    logits0 = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo))(staged, toks)
    logits, meas = measure_prefill(cfg, staged, toks, plan, topo)

# the hooked replay computes the SAME program: bit-identical logits
assert (np.asarray(logits) == np.asarray(logits0)).all()
# telemetry-aligned layout: [N, T] with T = M + N - 1
assert meas.tick_s.shape == (n, m + n - 1)
valid = meas.valid(m)
assert valid.sum() == n * m
# lockstep ticks all beaconed -> every VALID cell carries a real positive
# span (the tick's wall clock, broadcast to the stages active that tick);
# bubble cells stay exactly zero
assert (meas.tick_s[valid] > 0).all()
assert (meas.tick_s[~valid] == 0).all()
assert meas.total() > 0
assert meas.to_dict()["tick_s"][0][0] == float(meas.tick_s[0, 0])

# timed-kernel attribution: per-tag totals ride count_launches(timed=True).
# The default jnp backend launches no Pallas kernels, so time the pallas
# plan — its self block + pool scan are what the tag stream attributes.
plan_pl = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n,
                                             attn_backend="pallas"))
with compat.set_mesh(mesh):
    _, meas_k = measure_prefill(cfg, staged, toks, plan_pl, topo,
                                timed_kernels=True)
assert "chunk_attention" in meas_k.kernel_s, meas_k.kernel_s
assert all(v >= 0 for v in meas_k.kernel_s.values())
print("PASS")
"""


def test_measured_profile_matches_run():
    """Tentpole acceptance (measure leg): the timed replay is bit-identical
    to the bare pipeline, and its spans land index-aligned with the
    telemetry profiles, with per-kernel-tag attribution available."""
    _run(SNIPPET_MEASURED)


SNIPPET_FIT_FROM_MEASURED = """
import numpy as np, jax
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import costmodel as cm
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.obs import calibrate as cal
from repro.obs.profile import measure_prefill

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
plan = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n))
staged = pp.stage_params(cfg, params, plan)
with compat.set_mesh(mesh):
    _, meas = measure_prefill(cfg, staged, toks, plan, topo)

# end-to-end closed loop on REAL spans: fit -> calibrated profile whose
# L2 residual on its own measurements never beats the datasheet's. (The
# fit minimizes L2, not MAPE, so the L2 residual is the guaranteed
# quantity; the non-positive-rate clamp can substitute nominal theta
# components, which we detect by exact equality and allow slack for.)
sm = cm.StageModel.build(cfg, n, 1)
chunks = [s // m] * m
fit = cal.fit_profile(sm, chunks, meas, cm.WSC_PAPER)
assert fit.profile.name.endswith("+cal")
assert len(fit.rows) == n * m
assert np.isfinite(fit.mape_calibrated) and np.isfinite(fit.mape_nominal)
X, y, rows = cal.design_matrix(sm, chunks, cm.WSC_PAPER, meas.tick_s)
r_cal = float(np.linalg.norm(fit.residual_s))
r_nom = float(np.linalg.norm(y - X @ fit.theta_nominal))
clamped = fit.theta == fit.theta_nominal
if not clamped.any():
    assert r_cal <= r_nom * (1 + 1e-9), (r_cal, r_nom)
else:
    assert r_cal <= r_nom * 1.5, (r_cal, r_nom, clamped)
print("PASS")
"""


def test_fit_from_real_measured_spans():
    """The loop closes on real (host-clock) spans too: fitting never does
    worse than the nominal profile on the spans it was fit to."""
    _run(SNIPPET_FIT_FROM_MEASURED)


# ------------------------------------------------- health sentinels (8 chips)

SNIPPET_HEALTH = """
import re
import numpy as np, jax
import jax.numpy as jnp
from repro import compat
from repro.compat import AxisType
from repro.configs.base import RunConfig, get_smoke_config, replace
from repro.core import pipeline as pp
from repro.models.api import build_model
from repro.models.topology import Topology
from repro.obs.health import HealthMonitor

cfg = replace(get_smoke_config("qwen3-8b"), dtype="float32")
n, m, s, b = 8, 8, 128, 2
mesh = compat.make_mesh((n, 1), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)
topo = Topology(mesh=mesh)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
plan = pp.build_plan(cfg, n, s, RunConfig(num_chunks=m, num_stages=n))
staged = pp.stage_params(cfg, params, plan)

COLL = re.compile(r"collective-permute|collective_permute|all-reduce|"
                  r"all_reduce|all-gather|all_gather|reduce-scatter|"
                  r"reduce_scatter")
def lowered(monitor):
    with compat.set_mesh(mesh):
        return jax.jit(lambda st, tk: pp.prefill_pipeline(
            cfg, st, tk, plan, topo, health=monitor)).lower(staged, toks)

# 1) disarmed (health=None) == the plain pipeline, same HLO text: ZERO
#    extra anything, not merely zero extra collectives
off = lowered(None).as_text()
with compat.set_mesh(mesh):
    base = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo)).lower(staged, toks).as_text()
assert off == base
# 2) armed: the per-stage isfinite reduction is shard-local arithmetic —
#    zero extra collectives even when the sentinel IS traced
mon = HealthMonitor()
on = lowered(mon).as_text()
assert len(COLL.findall(on)) == len(COLL.findall(off)) > 0

# 3) armed on a healthy run: bit-identical logits, zero alerts
with compat.set_mesh(mesh):
    logits0 = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo))(staged, toks)
    logits1 = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo, health=mon))(staged, toks)
    jax.block_until_ready(logits1)
    jax.effects_barrier()
assert (np.asarray(logits0) == np.asarray(logits1)).all()
assert mon.alerts == [], mon.summary()

# 4) poisoned params -> nonfinite alerts with (stage, tick) attribution
bad = jax.tree_util.tree_map(
    lambda a: a * jnp.nan if jnp.issubdtype(a.dtype, jnp.floating) else a,
    staged)
mon2 = HealthMonitor()
with compat.set_mesh(mesh):
    out = jax.jit(lambda st, tk: pp.prefill_pipeline(
        cfg, st, tk, plan, topo, health=mon2))(bad, toks)
    jax.block_until_ready(out)
    jax.effects_barrier()
assert mon2.alerts, "NaN run fired no sentinel"
kinds = {a.kind for a in mon2.alerts}
assert kinds == {"nonfinite"}
assert all(a.severity == "crit" and a.stage is not None and
           a.tick is not None for a in mon2.alerts)
assert mon2.counts()["nonfinite"] == len(mon2.alerts)
print("PASS")
"""


def test_health_sentinels_zero_cost_and_nan_detection():
    """Tentpole acceptance (health leg): disarmed sentinels leave the HLO
    byte-identical; armed ones add zero collectives, keep logits
    bit-identical, stay silent on healthy runs, and catch NaN poisoning
    with per-(stage, tick) attribution."""
    _run(SNIPPET_HEALTH)


# ------------------------------------------------------ host-side sentinels

def test_health_drift_and_slo_sentinels():
    from repro.obs.health import HealthMonitor, slo_burn_rate
    from repro.obs.metrics import Histogram, MetricsRegistry
    from repro.obs.trace import TraceRecorder
    mon = HealthMonitor(ledger_threshold=0.01, burn_threshold=1.0)
    # ledger drift: 10% off the analytic model trips, 0.1% does not
    worst = mon.check_ledger({"ring": 1.10e9, "fetch": 1.000e8},
                             {"ring": 1.00e9, "fetch": 1.001e8})
    assert worst == pytest.approx(0.10)
    assert [a.kind for a in mon.alerts] == ["ledger_drift"]
    # SLO burn: 5 of 10 beyond a 1.0s SLO at target 99% -> burn 50x
    h = Histogram("ttft", buckets=(0.5, 1.0, 2.0))
    for v in (0.1,) * 5 + (1.5,) * 5:
        h.observe(v)
    assert slo_burn_rate(h, 1.0, target=0.99) == pytest.approx(50.0)
    burn = mon.check_slo(h, 1.0)
    assert burn == pytest.approx(50.0)
    assert mon.counts()["slo_burn"] == 1
    # empty histogram burns nothing
    assert slo_burn_rate(Histogram("x"), 1.0) == 0.0
    # exports: per-kind counters + burn gauge; one trace row per alert
    reg = MetricsRegistry()
    mon.to_metrics(reg)
    rows = {m.name: m for m in reg.metrics()}
    assert rows["repro_health_alerts_total"].value == 2
    assert rows["repro_health_ledger_drift_total"].value == 1
    assert rows["repro_health_slo_burn_rate"].value == pytest.approx(50.0)
    rec = TraceRecorder(enabled=True)
    mon.to_trace(rec)
    evs = rec.chrome_trace()["traceEvents"]
    alerts = [e for e in evs if e.get("cat") == "alert"]
    assert len(alerts) == 2 and all(e["pid"] == "health" for e in alerts)
    assert any(e["args"]["name"] == "health sentinels"
               for e in evs if e["ph"] == "M")


def test_health_occupancy_drift_sentinel():
    """A telemetry profile matching the analytic twin stays silent; a
    corrupted one trips occupancy_drift."""
    from repro.core import mbkr
    from repro.obs import telemetry as obs_t
    from repro.obs.health import HealthMonitor

    class FakePlan:
        num_chunks, num_stages = 8, 8
        p2 = mbkr.plan(8, 8).p2
        mode = "mocap"

    own, hosted = obs_t.analytic_occupancy(8, 8, FakePlan.p2)
    zeros = np.zeros_like(own)
    good = obs_t.TelemetryProfile({"own_chunks": own,
                                   "hosted_chunks": hosted})
    mon = HealthMonitor()
    assert mon.check_occupancy(good, FakePlan) == 0.0
    assert mon.alerts == []
    bad = obs_t.TelemetryProfile({"own_chunks": own * 2,
                                  "hosted_chunks": hosted})
    drift = mon.check_occupancy(bad, FakePlan)
    assert drift > mon.occupancy_threshold
    assert [a.kind for a in mon.alerts] == ["occupancy_drift"]
